//! Integration tests over the real AOT artifacts: runtime loading, the
//! training loop, evaluation, probes, and the quantization effects the paper
//! reports — exercised end-to-end through PJRT. These are the tests that
//! prove the three layers compose.
//!
//! All tests skip gracefully when `make artifacts` hasn't run.

use qpretrain::config::{BitWidths, QuantRunCfg, TrainHp};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::eval::EvalQuant;
use qpretrain::model::init_state;
use qpretrain::runtime::{lit_i32, lit_scalar, Runtime};
use qpretrain::train::{train, TrainCfg};
use qpretrain::util::artifact_dir;

fn runtime() -> Option<Runtime> {
    let dir = artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn hp(steps: usize) -> TrainHp {
    TrainHp {
        steps,
        eval_every: steps,
        eval_batches: 2,
        log_every: usize::MAX,
        ..TrainHp::default()
    }
}

fn qcfg(structure: &str, w: u32, a: u32, g: u32, m1: u32, m2: u32) -> QuantRunCfg {
    QuantRunCfg {
        structure: structure.to_string(),
        bits: BitWidths {
            weights: w,
            acts: a,
            grads: g,
            m1,
            m2,
        },
    }
}

#[test]
fn manifest_has_all_t4_structures() {
    let Some(rt) = runtime() else { return };
    for s in [
        "base", "w_pt", "w_pc", "a_pt", "a_ptok", "a_ptok_asym", "a_pc", "g_pt",
        "g_ptok", "g_ptok_actgrad", "m1_pt", "m1_pc", "m2_pt", "m2_pc", "wa", "wag",
        "w_pc_pallas",
    ] {
        assert!(
            rt.manifest.artifacts.contains_key(&format!("t4/train/{s}")),
            "missing t4/train/{s}"
        );
    }
    let m = rt.manifest.model("t4").unwrap();
    assert_eq!(m.params.len(), 16);
    assert_eq!(m.vocab, 512);
}

#[test]
fn train_step_signature_roundtrip() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("t4").unwrap().clone();
    let exe = rt.exec("t4/train/base").unwrap();
    assert_eq!(exe.info.inputs.len(), 3 * model.params.len() + 9);
    assert_eq!(exe.info.outputs.len(), 3 * model.params.len() + 2);

    // one manual step: outputs must parse and loss ~ ln(V)
    let state = init_state(&model, 7).to_literals(&model).unwrap();
    let mut it = BatchIter::new(CorpusCfg::train_default(model.vocab), model.batch, model.seq);
    let b = it.next_batch();
    let x = lit_i32(&b.x, &[b.batch, b.seq]).unwrap();
    let y = lit_i32(&b.y, &[b.batch, b.seq]).unwrap();
    let lr = lit_scalar(0.0);
    let t = lit_scalar(1.0);
    let q: Vec<xla::Literal> = (0..5).map(|_| lit_scalar(1.0)).collect();
    let mut inputs: Vec<&xla::Literal> = state.iter().collect();
    inputs.extend([&x, &y, &lr, &t]);
    for qq in &q {
        inputs.push(qq);
    }
    let out = exe.run(&inputs).unwrap();
    let loss = qpretrain::runtime::scalar_f32(&out[3 * model.params.len()]).unwrap();
    assert!((loss - (model.vocab as f32).ln()).abs() < 0.3, "init loss {loss}");
}

#[test]
fn zero_lr_step_preserves_params_through_pjrt() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("t4").unwrap().clone();
    let host = init_state(&model, 9);
    let state = host.to_literals(&model).unwrap();
    let exe = rt.exec("t4/train/base").unwrap();
    let mut it = BatchIter::new(CorpusCfg::train_default(model.vocab), model.batch, model.seq);
    let b = it.next_batch();
    let x = lit_i32(&b.x, &[b.batch, b.seq]).unwrap();
    let y = lit_i32(&b.y, &[b.batch, b.seq]).unwrap();
    let lr = lit_scalar(0.0);
    let t = lit_scalar(1.0);
    let q: Vec<xla::Literal> = (0..5).map(|_| lit_scalar(1.0)).collect();
    let mut inputs: Vec<&xla::Literal> = state.iter().collect();
    inputs.extend([&x, &y, &lr, &t]);
    for qq in &q {
        inputs.push(qq);
    }
    let out = exe.run(&inputs).unwrap();
    let roundtrip = qpretrain::model::HostState::from_literals(&model, &out, 1).unwrap();
    assert_eq!(roundtrip.params, host.params, "params changed at lr=0");
}

#[test]
fn short_training_reduces_loss_baseline_and_wa() {
    let Some(rt) = runtime() else { return };
    for (structure, bits) in [
        ("base", BitWidths::none()),
        ("wa", BitWidths { weights: 8, acts: 8, ..BitWidths::none() }),
        ("w_pc_pallas", BitWidths { weights: 8, ..BitWidths::none() }),
    ] {
        let cfg = TrainCfg::new("t4", QuantRunCfg { structure: structure.into(), bits }, hp(25));
        let r = train(&rt, &cfg).unwrap();
        assert!(!r.diverged, "{structure} diverged");
        assert!(
            r.final_loss() < r.losses[0] - 0.5,
            "{structure}: no learning ({:.3} -> {:.3})",
            r.losses[0],
            r.final_loss()
        );
    }
}

#[test]
fn w2_per_tensor_worse_than_w8() {
    let Some(rt) = runtime() else { return };
    let w8 = train(&rt, &TrainCfg::new("t4", qcfg("w_pt", 8, 0, 0, 0, 0), hp(25))).unwrap();
    let w2 = train(&rt, &TrainCfg::new("t4", qcfg("w_pt", 2, 0, 0, 0, 0), hp(25))).unwrap();
    assert!(
        w2.final_loss() > w8.final_loss() + 0.02,
        "2-bit ({:.3}) should trail 8-bit ({:.3})",
        w2.final_loss(),
        w8.final_loss()
    );
}

#[test]
fn m2_per_tensor_8bit_unstable() {
    let Some(rt) = runtime() else { return };
    let base = train(&rt, &TrainCfg::new("t4", QuantRunCfg::baseline(), hp(25))).unwrap();
    let m2 = train(&rt, &TrainCfg::new("t4", qcfg("m2_pt", 0, 0, 0, 0, 8), hp(25))).unwrap();
    // paper Fig. 12: diverges or is far worse from the onset
    assert!(
        m2.diverged || m2.final_loss() > base.final_loss() + 0.5,
        "m2 quant unexpectedly healthy: {:.3} vs {:.3}",
        m2.final_loss(),
        base.final_loss()
    );
}

#[test]
fn eval_and_fewshot_run() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("t4").unwrap().clone();
    let cfg = TrainCfg::new("t4", QuantRunCfg::baseline(), hp(20));
    let r = train(&rt, &cfg).unwrap();
    let params = r.final_state.param_literals(&model).unwrap();

    let ppl = qpretrain::eval::perplexity_suite(
        &rt, "t4/eval/base", &model, &params, 2, EvalQuant::none(),
    )
    .unwrap();
    assert_eq!(ppl.len(), 4);
    for (k, v) in &ppl {
        assert!(v.is_finite() && *v > 1.0, "{k}: {v}");
    }
    // in-domain should beat the shifted domain
    assert!(ppl["synthwiki103"] < ppl["synthptb"] * 1.5);

    let fs = qpretrain::eval::fewshot_suite(
        &rt, "t4/eval/base", &model, &params, 8, 2, EvalQuant::none(),
    )
    .unwrap();
    assert_eq!(fs.per_task.len(), 10);
    for (t, acc, _) in &fs.per_task {
        assert!((0.0..=1.0).contains(acc), "{}: {acc}", t.name());
    }
}

#[test]
fn probes_and_analysis_run() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("t4").unwrap().clone();
    let state = init_state(&model, 3);
    let params = state.param_literals(&model).unwrap();

    let stats = qpretrain::analysis::activation_stats(&rt, &model, &params).unwrap();
    assert_eq!(stats.proj_in_channel_max.len(), model.d_model);
    assert_eq!(stats.fc2_in_channel_max.len(), model.d_ff);
    assert!(stats.fc2_in_max.is_finite());

    let schemes = vec![(
        "int8 ptok".to_string(),
        qpretrain::config::Scheme::new(8, qpretrain::config::Granularity::PerToken),
    )];
    let g = qpretrain::analysis::gradient_stats(&rt, &model, &params, &schemes).unwrap();
    assert!(g.weight_grad_hist.total() > 0);
    assert!((0.0..=1.0).contains(&g.weight_grad_sparsity));
    assert!(g.quant_rel_err[0].1.is_finite());
}

#[test]
fn ptq_weights_degrade_monotonically() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("t4").unwrap().clone();
    let cfg = TrainCfg::new("t4", QuantRunCfg::baseline(), hp(25));
    let r = train(&rt, &cfg).unwrap();
    use qpretrain::config::Granularity::PerChannel;
    let fp = qpretrain::eval::perplexity_suite(
        &rt, "t4/eval/base", &model,
        &r.final_state.param_literals(&model).unwrap(), 2, EvalQuant::none(),
    )
    .unwrap()["synthwiki103"];
    let p8 = qpretrain::ptq::ptq_weights_ppl(&rt, &model, &r.final_state, 8, PerChannel, 2)
        .unwrap()["synthwiki103"];
    let p2 = qpretrain::ptq::ptq_weights_ppl(&rt, &model, &r.final_state, 2, PerChannel, 2)
        .unwrap()["synthwiki103"];
    assert!(p8 < p2, "8-bit PTQ ({p8:.2}) must beat 2-bit ({p2:.2})");
    assert!(p8 < fp * 1.2, "8-bit PTQ ({p8:.2}) should be near fp ({fp:.2})");
}

#[test]
fn checkpoint_roundtrip_through_training() {
    let Some(rt) = runtime() else { return };
    let model = rt.manifest.model("t4").unwrap().clone();
    let dir = std::env::temp_dir().join("qpretrain_int_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = TrainCfg::new("t4", QuantRunCfg::baseline(), hp(10));
    cfg.out_dir = Some(dir.clone());
    cfg.save_ckpt = true;
    let r = train(&rt, &cfg).unwrap();
    let loaded = qpretrain::model::load_checkpoint(&dir.join("final.ckpt"), &model).unwrap();
    assert_eq!(loaded.step, 10);
    assert_eq!(loaded.params, r.final_state.params);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn deterministic_training_same_seed() {
    let Some(rt) = runtime() else { return };
    let a = train(&rt, &TrainCfg::new("t4", QuantRunCfg::baseline(), hp(8))).unwrap();
    let b = train(&rt, &TrainCfg::new("t4", QuantRunCfg::baseline(), hp(8))).unwrap();
    assert_eq!(a.losses, b.losses, "same seed must give identical losses");
    let mut hp2 = hp(8);
    hp2.seed += 1;
    let c = train(&rt, &TrainCfg::new("t4", QuantRunCfg::baseline(), hp2)).unwrap();
    assert_ne!(a.losses, c.losses);
}
