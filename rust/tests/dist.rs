//! Dist-trainer proof tests: an N-way data-parallel run must be
//! **bit-identical** to a single-process run at matched global batch —
//! losses, grad norms, validation, and the full final (params, m, v)
//! state — for both the f32 and the quantized int8 gradient exchange,
//! under both settings of the int8-accumulator knob, on all three
//! transports (filesystem processes, in-process channels, TCP sockets)
//! and with publish/backward overlap on or off. Plus loud-failure
//! coverage for the exchange protocols, including the socket join
//! handshake and mid-run peer death.

use std::collections::HashSet;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use qpretrain::backend::native::{int8_gemm_enabled, set_int8_gemm};
use qpretrain::config::{DistTransport, QuantRecipe, TrainHp};
use qpretrain::dist::frame::{self, Frame, WireNode, WireTensor};
use qpretrain::dist::socket::{
    self, encode_handshake, epoch_nonce, Handshake, HS_VERSION, MSG_ABORT, MSG_FRAME, MSG_HELLO,
};
use qpretrain::dist::{dist_train, wire_policy, Exchange, Transport};
use qpretrain::runtime::Runtime;
use qpretrain::train::{TrainCfg, TrainResult};

/// The dist launcher resolves the worker binary through `QPRETRAIN_BIN`
/// when set — tests run from the test harness binary, whose
/// `current_exe()` is *not* the CLI.
fn setup_bin() {
    std::env::set_var("QPRETRAIN_BIN", env!("CARGO_BIN_EXE_qpretrain"));
}

/// `set_int8_gemm` is process-global; knob-toggling tests serialize on
/// this so the parallel test harness can't interleave them.
static INT8_LOCK: Mutex<()> = Mutex::new(());

fn hp(steps: usize, dp: usize) -> TrainHp {
    TrainHp {
        steps,
        eval_every: steps,
        eval_batches: 2,
        log_every: usize::MAX,
        dp,
        ..TrainHp::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qpretrain_dist_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn run_t(
    spec: &str,
    dp: usize,
    out: Option<PathBuf>,
    transport: DistTransport,
    overlap: bool,
) -> TrainResult {
    let rt = Runtime::native();
    let mut h = hp(5, dp);
    h.dist_transport = transport;
    h.dist_overlap = overlap;
    let mut cfg = TrainCfg::new("micro", QuantRecipe::parse(spec).unwrap(), h);
    cfg.out_dir = out;
    dist_train(&rt, &cfg).unwrap()
}

fn run(spec: &str, dp: usize, out: Option<PathBuf>) -> TrainResult {
    run_t(spec, dp, out, DistTransport::Filesystem, true)
}

fn assert_bit_identical(a: &TrainResult, b: &TrainResult, what: &str) {
    let bits64 = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits64(&a.losses), bits64(&b.losses), "{what}: losses");
    assert_eq!(bits64(&a.gnorms), bits64(&b.gnorms), "{what}: gnorms");
    assert_eq!(
        a.val
            .iter()
            .map(|(s, l)| (*s, l.to_bits()))
            .collect::<Vec<_>>(),
        b.val
            .iter()
            .map(|(s, l)| (*s, l.to_bits()))
            .collect::<Vec<_>>(),
        "{what}: val"
    );
    assert_eq!(a.diverged, b.diverged, "{what}: diverged");
    assert_eq!(a.spike_steps, b.spike_steps, "{what}: spikes");
    for (name, ta, tb) in [
        ("params", &a.final_state.params, &b.final_state.params),
        ("m", &a.final_state.m, &b.final_state.m),
        ("v", &a.final_state.v, &b.final_state.v),
    ] {
        assert_eq!(ta.len(), tb.len(), "{what}: {name} tensor count");
        for (i, (x, y)) in ta.iter().zip(tb.iter()).enumerate() {
            let xb = x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            let yb = y.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(xb, yb, "{what}: {name}[{i}] differs");
        }
    }
}

/// dp in {2, 3} vs dp=1, for the f32 wire (base) and the quantized int8
/// wire (w8a8g8), under both int8-accumulator settings. Also checks the
/// exchange dir is cleaned up after success.
#[test]
fn nway_run_is_bit_identical_to_single_process() {
    setup_bin();
    let _g = INT8_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = int8_gemm_enabled();
    for spec in ["base", "w8a8g8"] {
        for int8 in [true, false] {
            set_int8_gemm(int8);
            let reference = run(spec, 1, None);
            assert!(
                !reference.losses.is_empty() && !reference.val.is_empty(),
                "reference run produced no data"
            );
            for dp in [2usize, 3] {
                let out = tmp_dir(&format!("{spec}_i{}_dp{dp}", u8::from(int8)));
                let r = run(spec, dp, Some(out.clone()));
                assert_bit_identical(
                    &reference,
                    &r,
                    &format!("{spec} int8={int8} dp={dp}"),
                );
                assert!(
                    !out.join("dist").exists(),
                    "exchange dir must be removed after a clean run"
                );
                std::fs::remove_dir_all(&out).ok();
            }
        }
    }
    set_int8_gemm(prev);
}

/// The transport and the overlap knob are wall-clock choices only: every
/// {filesystem, channel, socket} x {overlap on, off} combination at dp=2
/// — plus channel and socket at dp=3 and the f32 wire on channel and
/// socket — reproduces the dp=1 trajectory bit-for-bit. The channel and
/// socket transports need no out dir at all (the socket legs spawn real
/// `dist-worker` subprocesses dialing rank 0 over loopback).
#[test]
fn every_transport_and_overlap_combination_is_bit_identical() {
    setup_bin();
    let _g = INT8_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = int8_gemm_enabled();
    set_int8_gemm(true);

    let reference = run_t("w8a8g8", 1, None, DistTransport::Filesystem, true);
    for transport in [
        DistTransport::Filesystem,
        DistTransport::Channel,
        DistTransport::Socket,
    ] {
        for overlap in [true, false] {
            let out = (transport == DistTransport::Filesystem).then(|| {
                tmp_dir(&format!("matrix_{}_{}", transport.as_str(), u8::from(overlap)))
            });
            let r = run_t("w8a8g8", 2, out.clone(), transport, overlap);
            assert_bit_identical(
                &reference,
                &r,
                &format!("w8a8g8 dp=2 {} overlap={overlap}", transport.as_str()),
            );
            if let Some(out) = out {
                std::fs::remove_dir_all(&out).ok();
            }
        }
    }
    // channel and socket at dp=3 (odd shard split -> carry nodes on the
    // wire, and on socket the hub relays worker<->worker frames)
    for transport in [DistTransport::Channel, DistTransport::Socket] {
        let r = run_t("w8a8g8", 3, None, transport, true);
        assert_bit_identical(
            &reference,
            &r,
            &format!("w8a8g8 dp=3 {}", transport.as_str()),
        );
    }
    // f32 wire over channels and sockets
    let f32_ref = run_t("base", 1, None, DistTransport::Filesystem, true);
    for transport in [DistTransport::Channel, DistTransport::Socket] {
        let r = run_t("base", 2, None, transport, true);
        assert_bit_identical(&f32_ref, &r, &format!("base dp=2 {}", transport.as_str()));
    }

    set_int8_gemm(prev);
}

#[test]
fn wire_policy_is_selected_by_the_recipe_alone() {
    let p = |s: &str| wire_policy(&QuantRecipe::parse(s).unwrap());
    assert!(p("base").is_none());
    assert!(p("w8a8").is_none());
    assert!(p("w8a8g8").is_some());
    assert!(p("g8_ptok").is_some());
    assert!(p("g8_pc").is_none());
    assert!(p("w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc").is_some());
}

#[test]
fn dist_train_requires_an_out_dir_for_dp_over_1() {
    setup_bin();
    let rt = Runtime::native();
    let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(1, 2));
    let err = dist_train(&rt, &cfg).unwrap_err().to_string();
    assert!(err.contains("out dir"), "unexpected error: {err}");
}

#[test]
fn dist_train_rejects_dp_beyond_the_batch() {
    setup_bin();
    let rt = Runtime::native();
    // micro has a global batch of 4; dp=5 cannot shard it
    let mut cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(1, 5));
    cfg.out_dir = Some(tmp_dir("overdp"));
    let err = dist_train(&rt, &cfg).unwrap_err().to_string();
    assert!(err.contains("exceeds the global batch"), "unexpected error: {err}");
    std::fs::remove_dir_all(cfg.out_dir.unwrap()).ok();
}

fn empty_frame(step: u64, rank: u32, dp: u32) -> Frame {
    Frame {
        step,
        rank,
        dp,
        leaves: 4,
        part: 0,
        parts: 1,
        nodes: vec![WireNode {
            level: 1,
            idx: rank,
            loss: rank as f64,
            tensors: vec![WireTensor::F32(vec![1.0, 2.0, 3.0])],
        }],
    }
}

fn frame_files(dir: &std::path::Path) -> HashSet<String> {
    std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".frame"))
        .collect()
}

/// Two in-process `Exchange` peers over one dir: publish/collect round-trips
/// frames bit-exactly, and each rank's older frames are garbage-collected
/// once its next collect completes.
#[test]
fn exchange_roundtrips_and_garbage_collects() {
    let dir = tmp_dir("xchg");
    let timeout = Duration::from_secs(30);
    let mut ex0 = Exchange::new(&dir, 0, 2, timeout).unwrap();
    let mut ex1 = Exchange::new(&dir, 1, 2, timeout).unwrap();

    for step in 1..=2u64 {
        let f0 = empty_frame(step, 0, 2);
        let f1 = empty_frame(step, 1, 2);
        ex0.publish(&f0).unwrap();
        ex1.publish(&f1).unwrap();
        let got0 = ex0.collect(step).unwrap();
        let got1 = ex1.collect(step).unwrap();
        assert_eq!(got0, vec![f1]);
        assert_eq!(got1, vec![f0]);
    }
    // both ranks collected step 2, so their step-1 frames are gone
    let left = frame_files(&dir);
    assert!(
        !left.contains("step_1_rank_0_part_0.frame")
            && !left.contains("step_1_rank_1_part_0.frame"),
        "stale frames not garbage-collected: {left:?}"
    );
    assert!(
        left.contains("step_2_rank_0_part_0.frame")
            && left.contains("step_2_rank_1_part_0.frame"),
        "current frames must survive: {left:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression for the garbage collector: over a longer run — including
/// multi-part (overlap-style) steps — the exchange dir must never hold
/// more than two steps' worth of live frames (2 * dp * parts files), and
/// step 1 must be collected like any other step, not special-cased away.
#[test]
fn exchange_dir_stays_bounded_over_a_run() {
    let dir = tmp_dir("gc_bound");
    let timeout = Duration::from_secs(30);
    let dp = 2u32;
    let parts = 2u32;
    let mut exs = [
        Exchange::new(&dir, 0, dp as usize, timeout).unwrap(),
        Exchange::new(&dir, 1, dp as usize, timeout).unwrap(),
    ];
    for step in 1..=4u64 {
        for (rank, ex) in exs.iter_mut().enumerate() {
            for part in 0..parts {
                let mut f = empty_frame(step, rank as u32, dp);
                f.part = part;
                f.parts = parts;
                ex.publish(&f).unwrap();
            }
        }
        // the high-water mark: this step's frames are published, last
        // step's are not yet collected away
        let live = frame_files(&dir).len() as u32;
        assert!(
            live <= 2 * dp * parts,
            "step {step}: {live} live frames exceed the 2-step bound of {}",
            2 * dp * parts
        );
        for ex in exs.iter_mut() {
            let got = ex.collect(step).unwrap();
            assert_eq!(got.len(), 1);
            assert_eq!(got[0].nodes.len(), parts as usize, "parts must merge");
        }
        // from step 2 on, everything older than the current step is gone
        let stale: Vec<String> = frame_files(&dir)
            .into_iter()
            .filter(|n| !n.starts_with(&format!("step_{step}_")))
            .collect();
        if step > 1 {
            assert!(stale.is_empty(), "step {step}: stale frames survive: {stale:?}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A zero timeout means "the frame must already be there": a missing
/// frame fails immediately (no silent extra poll round — the deadline
/// check is `>=`, not `>`), while an already-published frame still
/// collects fine.
#[test]
fn zero_timeout_fails_fast_but_reads_published_frames() {
    let dir = tmp_dir("zero_to_miss");
    let mut ex = Exchange::new(&dir, 0, 2, Duration::ZERO).unwrap();
    let t = std::time::Instant::now();
    let err = ex.collect(1).unwrap_err().to_string();
    assert!(err.contains("timed out"), "unexpected error: {err}");
    assert!(
        t.elapsed() < Duration::from_millis(200),
        "zero timeout must not wait ({:?})",
        t.elapsed()
    );
    std::fs::remove_dir_all(&dir).ok();

    let dir = tmp_dir("zero_to_hit");
    let mut ex1 = Exchange::new(&dir, 1, 2, Duration::ZERO).unwrap();
    ex1.publish(&empty_frame(1, 1, 2)).unwrap();
    let mut ex0 = Exchange::new(&dir, 0, 2, Duration::ZERO).unwrap();
    let got = ex0.collect(1).unwrap();
    assert_eq!(got, vec![empty_frame(1, 1, 2)]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exchange_times_out_loudly() {
    let dir = tmp_dir("timeout");
    let mut ex = Exchange::new(&dir, 0, 2, Duration::from_millis(60)).unwrap();
    let err = ex.collect(1).unwrap_err().to_string();
    assert!(err.contains("timed out"), "unexpected error: {err}");
    // the timeout must also have dropped the ABORT marker for peers
    assert!(dir.join("ABORT").exists(), "timeout must abort the peers too");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exchange_propagates_peer_aborts() {
    let dir = tmp_dir("abort");
    let mut ex = Exchange::new(&dir, 0, 2, Duration::from_secs(30)).unwrap();
    std::fs::write(dir.join("ABORT"), "rank 1: worker was killed").unwrap();
    let err = ex.collect(1).unwrap_err().to_string();
    assert!(
        err.contains("worker was killed"),
        "abort message must propagate: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A corrupted frame on disk must fail the collect, not feed garbage into
/// the reduction.
#[test]
fn exchange_rejects_corrupt_frames() {
    let dir = tmp_dir("corrupt");
    let mut ex1 = Exchange::new(&dir, 1, 2, Duration::from_secs(30)).unwrap();
    ex1.publish(&empty_frame(1, 1, 2)).unwrap();
    // flip one payload byte behind the codec's back
    let path = dir.join("step_1_rank_1_part_0.frame");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let mut ex0 = Exchange::new(&dir, 0, 2, Duration::from_secs(30)).unwrap();
    assert!(ex0.collect(1).is_err(), "corrupt frame must be rejected");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// socket transport: loud-failure coverage over real TCP
// ---------------------------------------------------------------------------

/// Write one `kind u8 | len u32 | payload` socket message (the raw-client
/// side of the transport's stream framing, hand-rolled so these tests
/// exercise the wire format itself, not the transport's own writer).
fn wmsg(s: &mut TcpStream, kind: u8, payload: &[u8]) {
    let mut b = vec![kind];
    b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    b.extend_from_slice(payload);
    s.write_all(&b).unwrap();
}

fn read_exact_or_eof(s: &mut TcpStream, buf: &mut [u8]) -> Option<()> {
    let mut filled = 0;
    while filled < buf.len() {
        match s.read(&mut buf[filled..]) {
            Ok(0) => return None,
            Ok(n) => filled += n,
            Err(e) => panic!("test socket read failed: {e}"),
        }
    }
    Some(())
}

/// Read one socket message; `None` on a clean close.
fn rmsg(s: &mut TcpStream) -> Option<(u8, Vec<u8>)> {
    let mut hdr = [0u8; 5];
    read_exact_or_eof(s, &mut hdr)?;
    let len = u32::from_le_bytes(hdr[1..5].try_into().unwrap()) as usize;
    let mut payload = vec![0u8; len];
    read_exact_or_eof(s, &mut payload)?;
    Some((hdr[0], payload))
}

/// A real `dist-worker` subprocess killed mid-step: the leader's next
/// collect must fail with the hung-up-peer error as soon as the kernel
/// delivers the dead process's FIN — not by burning the 60 s deadline.
#[test]
fn socket_worker_killed_mid_step_dies_loudly_not_by_timeout() {
    setup_bin();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = TrainCfg::new("micro", QuantRecipe::parse("base").unwrap(), hp(5, 2));
    let nonce = epoch_nonce(&cfg);
    let label = cfg.quant.label();
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_qpretrain"))
        .args([
            "dist-worker",
            "--rank",
            "1",
            "--dp",
            "2",
            "--model",
            "micro",
            "--quant",
            "base",
            "--steps",
            "5",
            "--seed",
            &cfg.hp.seed.to_string(),
            "--threads",
            "1",
            "--transport",
            "socket",
            "--connect",
            &addr.to_string(),
        ])
        .spawn()
        .unwrap();
    let mut leader = socket::listen(listener, 2, Duration::from_secs(60), nonce, &label).unwrap();
    // step 1: the worker publishes its shipment, then blocks collecting
    // ours (which never comes) — exactly mid-step
    let got = leader.collect(1).unwrap();
    assert_eq!(got.len(), 1, "one merged frame from the one worker");
    assert!(got.iter().all(|f| f.step == 1 && f.rank == 1));
    child.kill().unwrap();
    child.wait().unwrap();
    let t = Instant::now();
    let err = leader.collect(2).unwrap_err().to_string();
    assert!(err.contains("hung up"), "got: {err}");
    assert!(
        t.elapsed() < Duration::from_secs(10),
        "peer death must be detected by EOF, not the 60s deadline ({:?})",
        t.elapsed()
    );
}

/// A dialer carrying another run's epoch nonce is rejected with a typed
/// error on the leader, and told why over the wire (`ABRT`) — not left to
/// hang or silently dropped.
#[test]
fn socket_listen_rejects_a_dialer_from_a_different_run() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let dialer = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let hello = encode_handshake(&Handshake {
            version: HS_VERSION,
            dp: 2,
            rank: 1,
            nonce: 0xBAD,
            recipe: "w8a8g8".to_string(),
        });
        wmsg(&mut s, MSG_HELLO, &hello);
        rmsg(&mut s)
    });
    let err = socket::listen(listener, 2, Duration::from_secs(30), 0x600D, "w8a8g8")
        .map(|_| ())
        .unwrap_err();
    let err = format!("{err:#}");
    assert!(err.contains("nonce mismatch"), "got: {err}");
    match dialer.join().unwrap() {
        Some((kind, text)) => {
            assert_eq!(kind, MSG_ABORT, "the rejection must be a typed ABRT");
            let text = String::from_utf8_lossy(&text).into_owned();
            assert!(text.contains("nonce mismatch"), "dialer saw: {text}");
        }
        None => panic!("dialer saw a silent close, not a typed ABRT"),
    }
}

/// A bit flip inside a QDGF frame that crossed TCP intact as far as the
/// stream framing is concerned must still die on the frame's own FNV-64
/// integrity check at collect.
#[test]
fn socket_rejects_a_corrupt_frame_after_a_valid_join() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let hello = encode_handshake(&Handshake {
            version: HS_VERSION,
            dp: 2,
            rank: 1,
            nonce: 9,
            recipe: "base".to_string(),
        });
        wmsg(&mut s, MSG_HELLO, &hello);
        let (kind, _) = rmsg(&mut s).expect("leader must answer the valid handshake");
        assert_eq!(kind, MSG_HELLO);
        let mut bytes = frame::encode(&empty_frame(1, 1, 2));
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        wmsg(&mut s, MSG_FRAME, &bytes);
        s // keep the connection open: the failure must be the integrity check
    });
    let mut leader = socket::listen(listener, 2, Duration::from_secs(30), 9, "base").unwrap();
    let _s = client.join().unwrap();
    leader.set_timeout(Duration::from_secs(30));
    let err = format!("{:#}", leader.collect(1).unwrap_err());
    assert!(err.contains("integrity"), "got: {err}");
}

/// `QPRETRAIN_DIST_TIMEOUT_SECS=0` semantics on the socket transport: a
/// collect with nothing queued fails immediately, it does not poll.
#[test]
fn socket_zero_timeout_fails_fast() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let w = std::thread::spawn(move || {
        socket::connect(addr, 1, 2, Duration::from_secs(30), 5, "base")
    });
    let mut leader = socket::listen(listener, 2, Duration::from_secs(30), 5, "base").unwrap();
    let _worker = w.join().unwrap().unwrap();
    leader.set_timeout(Duration::ZERO);
    let t = Instant::now();
    let err = leader.collect(1).unwrap_err().to_string();
    assert!(err.contains("timed out"), "got: {err}");
    assert!(
        t.elapsed() < Duration::from_millis(200),
        "zero timeout must not wait ({:?})",
        t.elapsed()
    );
}
