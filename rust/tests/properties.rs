//! Property-based tests (in-repo quickcheck harness) on the quantization
//! invariants the paper's methodology relies on, plus coordinator-state
//! invariants (LR schedule, config labelling, JSON round-trips).

use qpretrain::config::{cosine_lr, Granularity, TensorPolicy, TrainHp};
use qpretrain::quant::{params_sym, qdq_copy, quantize_one, PackedTensor};
use qpretrain::util::quickcheck::{check, check_with_shrink, gen, Config};
use qpretrain::util::rng::Rng;

fn cfg(cases: usize) -> Config {
    Config {
        cases,
        ..Config::default()
    }
}

fn gen_matrix(rng: &mut Rng) -> (Vec<f32>, usize, usize) {
    let rows = rng.range(1, 24);
    let cols = rng.range(1, 24);
    let mut data = gen::f32_vec_adversarial(rng, rows * cols);
    data.resize(rows * cols, 0.0);
    (data, rows, cols)
}

#[test]
fn prop_qdq_error_bounded_by_half_scale() {
    check(cfg(200), gen_matrix, |(data, rows, cols)| {
        for gran in [Granularity::PerTensor, Granularity::PerToken, Granularity::PerChannel] {
            let scheme = TensorPolicy::new(4, gran);
            let q = qdq_copy(data, *rows, *cols, scheme);
            for r in 0..*rows {
                for c in 0..*cols {
                    let x = data[r * cols + c];
                    let y = q[r * cols + c];
                    // group scale:
                    let group: Vec<f32> = match gran {
                        Granularity::PerTensor => data.clone(),
                        Granularity::PerToken => data[r * cols..(r + 1) * cols].to_vec(),
                        Granularity::PerChannel => {
                            (0..*rows).map(|rr| data[rr * cols + c]).collect()
                        }
                    };
                    let p = params_sym(&group, 7.0);
                    // within the clip range the error is at most s/2 (+eps)
                    if x.abs() <= 7.0 * p.scale {
                        if (y - x).abs() > p.scale / 2.0 + 1e-5 {
                            return false;
                        }
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_qdq_idempotent() {
    check(cfg(150), gen_matrix, |(data, rows, cols)| {
        for gran in [Granularity::PerTensor, Granularity::PerToken, Granularity::PerChannel] {
            for scheme in [TensorPolicy::new(4, gran), TensorPolicy::asym(4, gran)] {
                let once = qdq_copy(data, *rows, *cols, scheme);
                let twice = qdq_copy(&once, *rows, *cols, scheme);
                if once
                    .iter()
                    .zip(&twice)
                    .any(|(a, b)| (a - b).abs() > 1e-5 * a.abs().max(1.0))
                {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_qdq_preserves_sign_symmetric() {
    check(cfg(150), gen_matrix, |(data, rows, cols)| {
        let q = qdq_copy(data, *rows, *cols, TensorPolicy::new(8, Granularity::PerTensor));
        data.iter()
            .zip(&q)
            .all(|(&x, &y)| y == 0.0 || (x >= 0.0) == (y >= 0.0))
    });
}

#[test]
fn prop_qdq_monotone_on_grid() {
    // quantize_one is monotone non-decreasing in x for a fixed scale
    check(
        cfg(200),
        |rng| {
            let mut v = gen::f32_vec(rng, 32, 2.0);
            v.push(rng.normal_f32(0.0, 5.0));
            v
        },
        |v| {
            let p = params_sym(v, 7.0);
            let mut sorted = v.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let codes: Vec<f32> = sorted.iter().map(|&x| quantize_one(x, p, 7.0)).collect();
            codes.windows(2).all(|w| w[0] <= w[1])
        },
    );
}

#[test]
fn prop_packed_roundtrip_equals_fake_quant() {
    check_with_shrink(
        cfg(100),
        |rng| {
            let (d, r, c) = gen_matrix(rng);
            d.iter().map(|x| x * 0.1).collect::<Vec<f32>>().tap(r, c)
        },
        |t| {
            let mut out = Vec::new();
            if t.0.len() > 2 {
                out.push((t.0[..t.0.len() / 2].to_vec(), 1, t.0.len() / 2));
            }
            out
        },
        |(data, rows, cols)| {
            let grans = [Granularity::PerTensor, Granularity::PerToken, Granularity::PerChannel];
            for bits in [4u32, 8] {
                for gran in grans {
                    let scheme = TensorPolicy::new(bits, gran);
                    let packed = PackedTensor::quantize(data, *rows, *cols, scheme);
                    let deq = packed.dequantize();
                    let fake = qdq_copy(data, *rows, *cols, scheme);
                    if deq
                        .iter()
                        .zip(&fake)
                        .any(|(a, b)| (a - b).abs() > 1e-4 * b.abs().max(1e-3))
                    {
                        return false;
                    }
                }
            }
            true
        },
    );
}

trait Tap {
    fn tap(self, r: usize, c: usize) -> (Vec<f32>, usize, usize);
}
impl Tap for Vec<f32> {
    fn tap(mut self, r: usize, c: usize) -> (Vec<f32>, usize, usize) {
        self.resize(r * c, 0.0);
        (self, r, c)
    }
}

#[test]
fn prop_lr_schedule_within_bounds() {
    check(
        cfg(100),
        |rng| TrainHp {
            steps: rng.range(10, 2000),
            warmup: rng.range(1, 9),
            lr_max: rng.f64() * 1e-2 + 1e-5,
            lr_min: 1e-6,
            ..TrainHp::default()
        },
        |hp| {
            (0..=hp.steps).all(|s| {
                let lr = cosine_lr(hp, s);
                lr >= 0.0 && lr <= hp.lr_max * (1.0 + 1e-9)
            })
        },
    );
}

#[test]
fn prop_corpus_tokens_in_range_and_deterministic() {
    use qpretrain::data::{BatchIter, CorpusCfg};
    check(
        cfg(40),
        |rng| (rng.range(16, 512), rng.next_u64()),
        |(vocab, seed)| {
            let cfg = CorpusCfg {
                seed: *seed,
                ..CorpusCfg::train_default((*vocab).max(16))
            };
            let a = BatchIter::new(cfg.clone(), 2, 32).next_batch();
            let b = BatchIter::new(cfg.clone(), 2, 32).next_batch();
            a.x == b.x && a.x.iter().all(|&t| (t as usize) < cfg.usable_vocab())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    use qpretrain::util::json::{self, Value};
    check(
        cfg(100),
        |rng| {
            fn value(rng: &mut Rng, depth: usize) -> Value {
                let pick = if depth > 2 {
                    rng.below(4)
                } else {
                    rng.below(6)
                };
                match pick {
                    0 => Value::Null,
                    1 => Value::Bool(rng.bool_with(0.5)),
                    2 => Value::Num((rng.normal() * 100.0).round()),
                    3 => Value::Str(format!("s{}", rng.below(1000))),
                    4 => Value::Arr((0..rng.below(4)).map(|_| value(rng, depth + 1)).collect()),
                    _ => Value::Obj(
                        (0..rng.below(4))
                            .map(|i| (format!("k{i}"), value(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            value(rng, 0)
        },
        |v| json::parse(&v.to_json()).map(|p| p == *v).unwrap_or(false),
    );
}
