//! Codec tests for the typed quantization recipe: `parse(display(r)) == r`
//! over randomized recipes, every legacy artifact structure name parses as
//! an alias of the expected recipe, malformed strings error, and the
//! derived `forward_only()` view replaces the old eval-structure table.

use qpretrain::config::{Granularity, QuantRecipe, TensorPolicy};
use qpretrain::util::quickcheck::{check, Config};
use qpretrain::util::rng::Rng;

use Granularity::{PerChannel, PerTensor, PerToken};

fn gen_policy(rng: &mut Rng) -> TensorPolicy {
    let bits = [0u32, 2, 3, 4, 5, 6, 8, 12, 16, 24];
    TensorPolicy {
        bits: bits[rng.below(bits.len())],
        granularity: *rng.choose(&[PerTensor, PerToken, PerChannel]),
        asymmetric: rng.bool_with(0.5),
    }
}

fn gen_recipe(rng: &mut Rng) -> QuantRecipe {
    let mut r = QuantRecipe::none();
    if rng.bool_with(0.6) {
        r.weights = Some(gen_policy(rng));
    }
    if rng.bool_with(0.6) {
        r.acts = Some(gen_policy(rng));
    }
    if rng.bool_with(0.6) {
        r.grads = Some(gen_policy(rng));
    }
    if rng.bool_with(0.5) {
        r.m1 = Some(gen_policy(rng));
    }
    if rng.bool_with(0.5) {
        r.m2 = Some(gen_policy(rng));
    }
    // the act-grad flag is only meaningful with a gradient component
    r.quantize_act_grads = r.grads.is_some() && rng.bool_with(0.3);
    r
}

#[test]
fn prop_parse_display_roundtrip() {
    check(
        Config {
            cases: 500,
            ..Config::default()
        },
        gen_recipe,
        |r| QuantRecipe::parse(&r.to_string()).map(|p| p == *r).unwrap_or(false),
    );
}

#[test]
fn prop_label_parses_back_to_same_placement_and_bits() {
    check(
        Config {
            cases: 300,
            ..Config::default()
        },
        gen_recipe,
        |r| QuantRecipe::parse(&r.label()).map(|p| p == *r).unwrap_or(false),
    );
}

#[test]
fn all_legacy_aliases_parse_to_expected_recipes() {
    let w = |g| QuantRecipe {
        weights: Some(TensorPolicy::new(0, g)),
        ..QuantRecipe::none()
    };
    let a = |g| QuantRecipe {
        acts: Some(TensorPolicy::new(0, g)),
        ..QuantRecipe::none()
    };
    let g_ = |g| QuantRecipe {
        grads: Some(TensorPolicy::new(0, g)),
        ..QuantRecipe::none()
    };
    let m1 = |g| QuantRecipe {
        m1: Some(TensorPolicy::new(0, g)),
        ..QuantRecipe::none()
    };
    let m2 = |g| QuantRecipe {
        m2: Some(TensorPolicy::new(0, g)),
        ..QuantRecipe::none()
    };
    let wa = QuantRecipe {
        weights: Some(TensorPolicy::new(0, PerChannel)),
        acts: Some(TensorPolicy::new(0, PerToken)),
        ..QuantRecipe::none()
    };
    let expected: Vec<(&str, QuantRecipe)> = vec![
        ("base", QuantRecipe::none()),
        ("w_pt", w(PerTensor)),
        ("w_pc", w(PerChannel)),
        ("w_pc_pallas", w(PerChannel)),
        ("a_pt", a(PerTensor)),
        ("a_ptok", a(PerToken)),
        (
            "a_ptok_asym",
            QuantRecipe {
                acts: Some(TensorPolicy::asym(0, PerToken)),
                ..QuantRecipe::none()
            },
        ),
        ("a_pc", a(PerChannel)),
        ("g_pt", g_(PerTensor)),
        ("g_ptok", g_(PerToken)),
        (
            "g_ptok_actgrad",
            QuantRecipe {
                grads: Some(TensorPolicy::new(0, PerToken)),
                quantize_act_grads: true,
                ..QuantRecipe::none()
            },
        ),
        ("m1_pt", m1(PerTensor)),
        ("m1_pc", m1(PerChannel)),
        ("m2_pt", m2(PerTensor)),
        ("m2_pc", m2(PerChannel)),
        ("wa", wa),
        (
            "wag",
            QuantRecipe {
                grads: Some(TensorPolicy::new(0, PerToken)),
                ..wa
            },
        ),
    ];
    assert_eq!(expected.len(), QuantRecipe::LEGACY_ALIASES.len());
    for (name, want) in expected {
        assert!(
            QuantRecipe::LEGACY_ALIASES.contains(&name),
            "{name} missing from LEGACY_ALIASES"
        );
        let got = QuantRecipe::parse(name).unwrap();
        assert_eq!(got, want, "alias {name} parsed wrong");
        // every alias still maps back to an artifact structure
        let back = got.legacy_structure().expect("legacy alias has a structure");
        assert_eq!(
            QuantRecipe::parse(back).unwrap().placement(),
            got.placement(),
            "{name} -> {back} placement mismatch"
        );
    }
}

#[test]
fn malformed_recipes_error() {
    for bad in [
        "",
        "bogus",
        "w4",              // missing granularity
        "w4pc",            // missing separator
        "w4_pq",           // unknown granularity
        "w4_pc_actgrad",   // actgrad only valid on gradients
        "w4_pc+w8_pt",     // duplicate class
        "a8_ptok+a8_pt",   // duplicate class
        "w4_pc++a8_ptok",  // empty component
        "w1_pc",           // 1-bit symmetric would mean qmax == 0
        "w25_pc",          // past the f32-exact range
        "m1_8",            // missing granularity
        "w4_pc_asym_x",    // unknown modifier
    ] {
        assert!(QuantRecipe::parse(bad).is_err(), "{bad:?} should not parse");
    }
}

#[test]
fn forward_only_drops_backward_components() {
    let wag = QuantRecipe::parse("wag").unwrap();
    let f = wag.forward_only();
    assert!(f.weights.is_some() && f.acts.is_some());
    assert!(f.grads.is_none() && !f.quantize_act_grads);
    assert_eq!(f, QuantRecipe::parse("wa").unwrap());

    // with bit-widths attached
    assert_eq!(
        QuantRecipe::parse("w8a8g8").unwrap().forward_only(),
        QuantRecipe::parse("w8a8").unwrap()
    );

    // the full combined recipe evals under its W/A components
    let full = QuantRecipe::parse("w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc").unwrap();
    assert_eq!(
        full.forward_only(),
        QuantRecipe::parse("w4_pc+a8_ptok").unwrap()
    );
    // and no legacy structure can express it
    assert_eq!(full.legacy_structure(), None);
}

#[test]
fn qmax_matches_bit_widths() {
    let r = QuantRecipe::parse("w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc").unwrap();
    assert_eq!(r.qmax_scalars(), [7.0, 127.0, 127.0, 127.0, 127.0]);
    // placement-only components keep the fed-1.0 convention
    assert_eq!(QuantRecipe::parse("wa").unwrap().qmax_scalars(), [1.0; 5]);
    assert_eq!(TensorPolicy::new(24, PerTensor).qmax(), ((1u64 << 23) - 1) as f32);
}

#[test]
fn pallas_alias_matches_w_pc() {
    assert_eq!(
        QuantRecipe::parse("w_pc_pallas").unwrap(),
        QuantRecipe::parse("w_pc").unwrap()
    );
}

#[test]
fn actgrad_variant_sets_flag() {
    let s = QuantRecipe::parse("g_ptok_actgrad").unwrap();
    assert!(s.quantize_act_grads);
    assert_eq!(s.grads, Some(TensorPolicy::new(0, PerToken)));
    let s = QuantRecipe::parse("g8_ptok_actgrad").unwrap();
    assert!(s.quantize_act_grads);
    assert_eq!(s.grads, Some(TensorPolicy::new(8, PerToken)));
}
