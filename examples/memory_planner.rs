//! Memory planner: the paper's §3.3 memory model as a tool. Given a model
//! size and batch/seq, print the peak-memory composition and what 8-bit
//! weight/activation/optimizer storage would save (Figs. 2, 14, 15 analytic
//! substrate).
//!
//! Run: `cargo run --release --example memory_planner -- [small|medium|large|xl] [batch] [seq]`

use qpretrain::memmodel::{peak_memory, peak_memory_quantized, profile_model};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let size = args.get(1).map(String::as_str).unwrap_or("small");
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let seq: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(1024);

    let model = profile_model(size);
    println!(
        "GPT-2 {size}: {} layers, d={}, {:.0}M params, batch {batch} x seq {seq}\n",
        model.n_layer,
        model.d_model,
        model.n_params as f64 / 1e6
    );

    let fp = peak_memory(&model, batch, seq);
    println!("bf16 mixed-precision training (peak at {}):", fp.peak_phase);
    for (name, frac) in fp.fractions() {
        println!("  {name:<12} {:>8.2} GB  ({:.1}%)", gb(frac * fp.total() as f64), 100.0 * frac);
    }
    println!("  {:<12} {:>8.2} GB", "TOTAL", gb(fp.total() as f64));

    println!("\nwith the paper's recipe (8-bit weights+activations, 8-bit Adam states):");
    let q = peak_memory_quantized(&model, batch, seq, 8, 8, 8);
    for (name, frac) in q.fractions() {
        println!("  {name:<12} {:>8.2} GB  ({:.1}%)", gb(frac * q.total() as f64), 100.0 * frac);
    }
    println!("  {:<12} {:>8.2} GB", "TOTAL", gb(q.total() as f64));
    println!(
        "\nsavings: {:.2} GB ({:.1}% of peak)",
        gb((fp.total() - q.total()) as f64),
        100.0 * (fp.total() - q.total()) as f64 / fp.total() as f64
    );
}

fn gb(bytes: f64) -> f64 {
    bytes / 1e9
}
