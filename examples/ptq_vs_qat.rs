//! PTQ vs quantization-aware pre-training (paper §4.1 + Appendix C):
//! at 8 bits, post-training weight quantization is nearly free, but at
//! 4 bits training with quantization from scratch beats PTQ by a wide
//! margin. This example trains a baseline and a W4-per-channel QAT model,
//! then PTQs the baseline to 4 and 8 bits and compares perplexity — all on
//! the native backend.
//!
//! Run: `cargo run --release --example ptq_vs_qat -- [steps]`

use qpretrain::config::{Granularity, QuantRecipe, TrainHp};
use qpretrain::eval::perplexity_suite;
use qpretrain::ptq::ptq_weights_ppl;
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(80);
    let rt = Runtime::open_default()?;
    let model = rt.model("micro")?.clone();
    let hp = TrainHp {
        steps,
        ..TrainHp::default()
    };

    println!("== training fp32 baseline ({steps} steps) ==");
    let base_cfg = TrainCfg::new("micro", QuantRecipe::none(), hp.clone());
    let base = train(&rt, &base_cfg)?;

    println!("== training W4 per-channel QAT ==");
    let qat_cfg = TrainCfg::new("micro", QuantRecipe::parse("w4_pc")?, hp.clone());
    let qat = train(&rt, &qat_cfg)?;

    let key = "synthwiki103";
    let fp = perplexity_suite(&rt, &QuantRecipe::none(), &model, &base.final_state.params, 6)?;

    let qat_ppl = perplexity_suite(
        &rt,
        &qat_cfg.eval_recipe(),
        &model,
        &qat.final_state.params,
        6,
    )?;

    let ptq4 = ptq_weights_ppl(&rt, &model, &base.final_state, 4, Granularity::PerChannel, 6)?;
    let ptq8 = ptq_weights_ppl(&rt, &model, &base.final_state, 8, Granularity::PerChannel, 6)?;

    println!("\n| scheme | {key} ppl |");
    println!("|---|---|");
    println!("| fp32 baseline | {:.2} |", fp[key]);
    println!("| PTQ 8-bit per-channel | {:.2} |", ptq8[key]);
    println!("| PTQ 4-bit per-channel | {:.2} |", ptq4[key]);
    println!("| QAT 4-bit per-channel | {:.2} |", qat_ppl[key]);
    println!(
        "\npaper's claim: PTQ8 ~= baseline; QAT4 beats PTQ4. measured: \
         ptq8/base = {:.2}x, ptq4/qat4 = {:.2}x",
        ptq8[key] / fp[key],
        ptq4[key] / qat_ppl[key]
    );
    Ok(())
}
