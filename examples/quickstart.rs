//! Quickstart: pre-train a small model for a few steps with the paper's
//! recommended recipe (8-bit per-channel weights + 8-bit per-token
//! activations) on the pure-rust native backend, and print the loss curve.
//!
//! Run: `cargo run --release --example quickstart`
//! No artifacts, Python, or PJRT needed. (With `--features pjrt` and
//! `make artifacts`, the same code executes AOT HLO instead.)

use qpretrain::config::{QuantRecipe, TrainHp};
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    println!(
        "backend: {}, models: {:?}",
        rt.backend_name(),
        rt.manifest.models.keys().collect::<Vec<_>>()
    );

    let cfg = TrainCfg::new(
        "micro",
        // W8 per-channel + A8 per-token (paper §4.5); "w8a8" is the short
        // label for "w8_pc+a8_ptok"
        QuantRecipe::parse("w8a8")?,
        TrainHp {
            steps: 60,
            eval_every: 20,
            ..TrainHp::default()
        },
    );
    println!("training {} on {} ...", cfg.quant.label(), cfg.model);
    let r = train(&rt, &cfg)?;

    println!("\nstep  loss");
    for (i, l) in r.losses.iter().enumerate() {
        if (i + 1) % 10 == 0 {
            println!("{:>4}  {l:.4}", i + 1);
        }
    }
    for (s, v) in &r.val {
        println!("val @ {s}: {v:.4}");
    }
    println!(
        "\n{}: final loss {:.4} ({:.2} steps/s), diverged={}",
        r.label,
        r.final_loss(),
        r.steps_per_sec,
        r.diverged
    );
    Ok(())
}
