//! The paper's §4.5 recipe in action: train a small model under
//! (a) fp32 baseline, (b) W8A8 (recommended), (c) W8A8G8 (not recommended),
//! and compare validation loss + downstream accuracy — reproducing the
//! Fig. 13 conclusion that W+A quantization tracks the baseline while adding
//! gradient quantization costs real performance. Runs on the native backend.
//!
//! Run: `cargo run --release --example quant_recipe -- [steps]`

use qpretrain::config::{BitWidths, QuantRunCfg, TrainHp};
use qpretrain::eval::{fewshot_suite, EvalQuant};
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let rt = Runtime::open_default()?;
    let model = rt.model("micro")?.clone();

    let configs = [
        ("baseline", "base", BitWidths::none()),
        (
            "W8A8 (recipe)",
            "wa",
            BitWidths {
                weights: 8,
                acts: 8,
                ..BitWidths::none()
            },
        ),
        (
            "W8A8G8",
            "wag",
            BitWidths {
                weights: 8,
                acts: 8,
                grads: 8,
                ..BitWidths::none()
            },
        ),
    ];

    println!("| config | final val loss | few-shot avg |");
    println!("|---|---|---|");
    for (name, structure, bits) in configs {
        let cfg = TrainCfg::new(
            "micro",
            QuantRunCfg {
                structure: structure.into(),
                bits,
            },
            TrainHp {
                steps,
                ..TrainHp::default()
            },
        );
        let r = train(&rt, &cfg)?;
        let q = EvalQuant {
            qmax_w: bits.qmax_scalars()[0],
            qmax_a: bits.qmax_scalars()[1],
        };
        let fs = fewshot_suite(
            &rt,
            cfg.eval_structure(),
            &model,
            &r.final_state.params,
            16,
            2,
            q,
        )?;
        println!(
            "| {name} | {:.4} | {:.1}% |",
            r.final_val_loss(),
            100.0 * fs.average
        );
    }
    Ok(())
}
