//! The composable recipe API in action: every configuration below is one
//! recipe string. The first three reproduce the Fig. 13 conclusion (W+A
//! quantization tracks the baseline, adding gradient quantization costs
//! real performance); the last is the paper's *full combined* recipe —
//! weights, activations, gradients and both Adam moments quantized at once
//! — which the old closed structure vocabulary could not even express.
//! Runs on the native backend.
//!
//! Run: `cargo run --release --example quant_recipe -- [steps]`

use qpretrain::config::{QuantRecipe, TrainHp};
use qpretrain::eval::fewshot_suite;
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let rt = Runtime::open_default()?;
    let model = rt.model("micro")?.clone();

    let configs = [
        ("baseline", "base"),
        ("W8A8 (recipe)", "w8a8"),
        ("W8A8G8", "w8a8g8"),
        ("full combined", "w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc"),
    ];

    println!("| config | recipe | final val loss | few-shot avg |");
    println!("|---|---|---|---|");
    for (name, recipe) in configs {
        let cfg = TrainCfg::new(
            "micro",
            QuantRecipe::parse(recipe)?,
            TrainHp {
                steps,
                ..TrainHp::default()
            },
        );
        let r = train(&rt, &cfg)?;
        let fs = fewshot_suite(&rt, &cfg.eval_recipe(), &model, &r.final_state.params, 16, 2)?;
        println!(
            "| {name} | {} | {:.4} | {:.1}% |",
            cfg.quant,
            r.final_val_loss(),
            100.0 * fs.average
        );
    }
    Ok(())
}
