//! End-to-end validation (DESIGN.md §E-E2E): pre-train the ~100M-parameter
//! `gpt2s` model (12L/768d/12h, 8k vocab) for a few hundred steps with the
//! paper's recommended W8A8 recipe, logging the loss curve and throughput,
//! then evaluate perplexity on the held-out sets.
//!
//! Run: `cargo run --release --example pretrain_e2e -- [steps] [base|wa]`
//! Defaults to 150 steps of the `wa` (W8 per-channel + A8 per-token) recipe.
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::time::Instant;

use qpretrain::config::{BitWidths, QuantRunCfg, TrainHp};
use qpretrain::eval::{perplexity_suite, EvalQuant};
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};
use qpretrain::util::{artifact_dir, repo_root};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let structure = args.get(2).cloned().unwrap_or_else(|| "wa".to_string());

    let rt = Runtime::new(&artifact_dir())?;
    let model = rt.manifest.model("gpt2s")?.clone();
    println!(
        "gpt2s: {} layers, d={}, {} params ({:.1}M), batch {} x seq {}",
        model.n_layer,
        model.d_model,
        model.n_params,
        model.n_params as f64 / 1e6,
        model.batch,
        model.seq
    );

    let bits = if structure == "base" {
        BitWidths::none()
    } else {
        BitWidths {
            weights: 8,
            acts: 8,
            ..BitWidths::none()
        }
    };
    let mut cfg = TrainCfg::new(
        "gpt2s",
        QuantRunCfg {
            structure: structure.clone(),
            bits,
        },
        TrainHp {
            steps,
            lr_max: 6e-4, // the paper's GPT-2 learning rate
            lr_min: 6e-5,
            warmup: steps / 10,
            eval_every: (steps / 4).max(1),
            eval_batches: 2,
            log_every: 1,
            ..TrainHp::default()
        },
    );
    let out = repo_root().join("runs/e2e").join(format!("{structure}_s{steps}"));
    cfg.out_dir = Some(out.clone());
    cfg.save_ckpt = true;

    println!("training {} for {steps} steps ...", cfg.quant.label());
    let t0 = Instant::now();
    let r = train(&rt, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    let tokens_per_step = (model.batch * model.seq) as f64;

    println!("\nloss curve (every {} steps):", (steps / 20).max(1));
    for (i, l) in r.losses.iter().enumerate() {
        if (i + 1) % (steps / 20).max(1) == 0 {
            println!("  step {:>4}: {l:.4}", i + 1);
        }
    }
    println!(
        "\nthroughput: {:.2} steps/s = {:.0} tokens/s (wall {:.0}s)",
        r.steps_per_sec,
        r.steps_per_sec * tokens_per_step,
        wall
    );
    println!(
        "loss: {:.4} -> {:.4} (val {:.4}), diverged={}",
        r.losses.first().unwrap_or(&f64::NAN),
        r.final_loss(),
        r.final_val_loss(),
        r.diverged
    );

    let params = r.final_state.param_literals(&model)?;
    let q = EvalQuant {
        qmax_w: bits.qmax_scalars()[0],
        qmax_a: bits.qmax_scalars()[1],
    };
    let eval_art = if structure == "base" {
        "gpt2s/eval/base".to_string()
    } else {
        // gpt2s ships a base eval artifact; W8A8 fwd-quant eval uses qmax on
        // the t4-style wa eval only for t4 — for gpt2s we score unquantized.
        "gpt2s/eval/base".to_string()
    };
    let ppl = perplexity_suite(&rt, &eval_art, &model, &params, 2, q)?;
    println!("\nheld-out perplexity:");
    for (k, v) in &ppl {
        println!("  {k}: {v:.2}");
    }
    println!("\nrun artifacts -> {}", out.display());
    Ok(())
}
