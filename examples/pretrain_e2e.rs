//! End-to-end validation: pre-train a model with the paper's recommended
//! W8A8 recipe on the native backend, logging the loss curve and
//! throughput, then evaluate perplexity on the held-out sets.
//!
//! Run: `cargo run --release --example pretrain_e2e -- [steps] [recipe] [model]`
//! Defaults to 40 steps of the `w8a8` (W8 per-channel + A8 per-token)
//! recipe on the `t4` study model; any recipe string works, e.g.
//! `w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc`. `micro` is seconds-fast;
//! `gpt2s` (~100M params) is minutes-per-step on the single-threaded
//! native kernels and is the target of the `pjrt` feature build.

use std::time::Instant;

use qpretrain::config::{QuantRecipe, TrainHp};
use qpretrain::eval::perplexity_suite;
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};
use qpretrain::util::repo_root;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let recipe_str = args.get(2).cloned().unwrap_or_else(|| "w8a8".to_string());
    let recipe = QuantRecipe::parse(&recipe_str)?;
    let model_name = args.get(3).cloned().unwrap_or_else(|| "t4".to_string());

    let rt = Runtime::open_default()?;
    let model = rt.model(&model_name)?.clone();
    println!(
        "{} [{} backend]: {} layers, d={}, {} params ({:.2}M), batch {} x seq {}",
        model.name,
        rt.backend_name(),
        model.n_layer,
        model.d_model,
        model.n_params,
        model.n_params as f64 / 1e6,
        model.batch,
        model.seq
    );

    let mut cfg = TrainCfg::new(
        &model_name,
        recipe,
        TrainHp {
            steps,
            lr_max: 6e-4, // the paper's GPT-2 learning rate
            lr_min: 6e-5,
            warmup: (steps / 10).max(1),
            eval_every: (steps / 4).max(1),
            eval_batches: 2,
            log_every: 1,
            ..TrainHp::default()
        },
    );
    let out = repo_root()
        .join("runs/e2e")
        .join(format!("{model_name}_{}_s{steps}", cfg.quant.label()));
    cfg.out_dir = Some(out.clone());
    cfg.save_ckpt = true;

    println!("training {} for {steps} steps ...", cfg.quant.label());
    let t0 = Instant::now();
    let r = train(&rt, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    let tokens_per_step = (model.batch * model.seq) as f64;

    println!("\nloss curve (every {} steps):", (steps / 20).max(1));
    for (i, l) in r.losses.iter().enumerate() {
        if (i + 1) % (steps / 20).max(1) == 0 {
            println!("  step {:>4}: {l:.4}", i + 1);
        }
    }
    println!(
        "\nthroughput: {:.2} steps/s = {:.0} tokens/s (wall {:.0}s)",
        r.steps_per_sec,
        r.steps_per_sec * tokens_per_step,
        wall
    );
    println!(
        "loss: {:.4} -> {:.4} (val {:.4}), diverged={}",
        r.losses.first().unwrap_or(&f64::NAN),
        r.final_loss(),
        r.final_val_loss(),
        r.diverged
    );

    let ppl = perplexity_suite(&rt, &cfg.eval_recipe(), &model, &r.final_state.params, 2)?;
    println!("\nheld-out perplexity:");
    for (k, v) in &ppl {
        println!("  {k}: {v:.2}");
    }
    println!("\nrun artifacts -> {}", out.display());
    Ok(())
}
